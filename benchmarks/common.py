"""Shared harness for the paper-table benchmarks.

CPU-scale stand-in for the paper's CIFAR/ResNet experiments: an MLP
classifier on Gaussian-cluster data with label noise (overfits -> visible
generalization gaps), trained with the SAME distributed trainer the big
architectures use. Every paper table maps to one module here; the
qualitative orderings (DPPF vs baselines) are the reproduction target —
see EXPERIMENTS.md for the mapping to the paper's absolute numbers.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import DPPFConfig
from repro.core import pullpush as pp
from repro.data import classification_task
from repro.optim import make_optimizer
from repro.train import (
    RoundClock, TrainState, average_params, init_train_state, make_ddp_step,
    make_round_step, stacked_params,
)


# ---------------------------------------------------------------------------
# Small model
# ---------------------------------------------------------------------------

def mlp_init(key, dim, n_classes, width=64, depth=2):
    ks = jax.random.split(key, depth + 1)
    sizes = [dim] + [width] * depth + [n_classes]
    return {f"l{i}": {
        "w": jax.random.normal(ks[i], (sizes[i], sizes[i + 1])) * sizes[i] ** -0.5,
        "b": jnp.zeros((sizes[i + 1],)),
    } for i in range(depth + 1)}


def mlp_logits(params, x):
    n = len(params)
    for i in range(n):
        x = x @ params[f"l{i}"]["w"] + params[f"l{i}"]["b"]
        if i < n - 1:
            x = jax.nn.relu(x)
    return x


def mlp_loss(params, batch):
    logits = mlp_logits(params, batch["x"])
    labels = batch["y"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(lse - picked)
    return loss, {"loss": loss, "aux": jnp.float32(0.0)}


def error_pct(params, x, y):
    pred = jnp.argmax(mlp_logits(params, x), axis=-1)
    return float(100.0 * jnp.mean((pred != y).astype(jnp.float32)))


# ---------------------------------------------------------------------------
# Data plumbing
# ---------------------------------------------------------------------------

def worker_shards(n, M, seed=0):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(n)
    return np.array_split(idx, M)


def round_batches(data, shards, rng, tau, M, bs):
    xs, ys = [], []
    for _ in range(tau):
        bx, by = [], []
        for m in range(M):
            pick = rng.choice(shards[m], size=bs, replace=False)
            bx.append(np.asarray(data["x_train"])[pick])
            by.append(np.asarray(data["y_train"])[pick])
        xs.append(np.stack(bx))
        ys.append(np.stack(by))
    return {"x": jnp.asarray(np.stack(xs)), "y": jnp.asarray(np.stack(ys))}


# ---------------------------------------------------------------------------
# Training drivers
# ---------------------------------------------------------------------------

@dataclass
class RunResult:
    test_err: float
    train_err: float
    gen_gap: float
    comm_pct: float          # communication volume vs DDP (100 = per-step)
    consensus_dist: float
    history: dict
    params_avg: object
    workers: list            # per-worker param trees (for MV measure)
    seconds: float


def run_distributed(data, dcfg: DPPFConfig, *, M=4, bs=64, steps=400,
                    lr=0.05, momentum=0.9, wd=1e-3, sam_rho=0.0, width=64,
                    seed=0, qsr_eta_max=None, track_every=0):
    """Train with the shared trainer; returns RunResult. ``dcfg.consensus ==
    'ddp'`` uses the per-step gradient-averaging path."""
    key = jax.random.PRNGKey(seed)
    opt = make_optimizer("sgd", momentum=momentum, weight_decay=wd)
    p0 = lambda k: mlp_init(k, data["dim"], data["n_classes"], width)
    shards = worker_shards(len(data["x_train"]), M, seed)
    rng = np.random.default_rng(seed + 1)
    t0 = time.time()
    history = {"consensus_dist": [], "step": [], "pull": [], "push": [],
               "lam": []}

    if dcfg.consensus == "ddp":
        params = p0(key)
        state = TrainState(params=params, opt=opt.init(params), cstate={},
                           t=jnp.zeros((), jnp.int32))
        step_fn = jax.jit(make_ddp_step(mlp_loss, opt, base_lr=lr,
                                        total_steps=steps, sam_rho=sam_rho))
        for s in range(steps):
            b = round_batches(data, shards, rng, 1, M, bs)
            b = jax.tree.map(lambda a: a[0], b)
            state, _ = step_fn(state, b)
        avg = state.params
        workers = [state.params]
        comm_pct, cdist = 100.0, 0.0
    else:
        state = init_train_state(p0, opt, dcfg, M, key)
        # the RoundClock owns the round plan (fixed / remainder /
        # QSR-adaptive taus) and both schedules; the tau-oblivious round
        # builder retraces per batch shape, so jit's shape cache is the
        # per-tau compile cache (DESIGN.md §Round-clock)
        clock = RoundClock.from_config(dcfg, base_lr=lr, total_steps=steps)
        step_fn = jax.jit(make_round_step(mlp_loss, opt, dcfg, clock=clock,
                                          sam_rho=sam_rho), donate_argnums=0)
        for spec in clock.rounds:
            b = round_batches(data, shards, rng, spec.tau, M, bs)
            state, m = step_fn(state, b)
            if track_every and ((spec.index + 1) % track_every == 0):
                history["consensus_dist"].append(float(m["consensus_dist"]))
                history["pull"].append(float(m.get("pull_force", 0.0)))
                history["push"].append(float(m.get("push_force", 0.0)))
                history["lam"].append(float(m.get("lam_t", 0.0)))
                history["step"].append(spec.stop)
        avg = average_params(state)
        stacked = stacked_params(state)   # tree view whichever engine ran
        workers = [jax.tree.map(lambda a, i=i: a[i], stacked)
                   for i in range(M)]
        comm_pct = 100.0 * clock.total_rounds / steps
        cdist = float(pp.worker_dists(stacked).mean())

    train_err = error_pct(avg, data["x_train"], data["y_train"])
    test_err = error_pct(avg, data["x_test"], data["y_test"])
    return RunResult(test_err=test_err, train_err=train_err,
                     gen_gap=test_err - train_err, comm_pct=comm_pct,
                     consensus_dist=cdist, history=history, params_avg=avg,
                     workers=workers, seconds=time.time() - t0)


def default_data(seed=0, **kw):
    return classification_task(seed=seed, **kw)


def csv(name, **kv):
    print(name + "," + ",".join(f"{k}={v}" for k, v in kv.items()), flush=True)
