"""Paper §C.2: lambda-schedule ablation (fixed / increasing / decreasing),
plus the §7.2 round-clock row: QSR-adaptive tau on top of the best lambda
schedule (fewer consensus all-reduces at matching test error).
The paper finds increasing best (wide basins matter most near convergence);
note the paper's own text has the labels swapped in one sentence — we
report all three and the ordering."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv, default_data, run_distributed
from repro.configs import DPPFConfig

SEEDS = (42, 182, 437)
QSR_BETA = 0.05   # with lr=0.05 cosine: tau stays 4 early, grows as lr decays


def run(steps=400, M=4):
    data = default_data()
    out = {}
    for sched in ("fixed", "increasing", "decreasing"):
        errs = [run_distributed(
            data, DPPFConfig(alpha=0.1, lam=0.5, tau=4, lam_schedule=sched),
            M=M, steps=steps, seed=s).test_err for s in SEEDS]
        out[sched] = (float(np.mean(errs)), float(np.std(errs)))
        csv("ablate_schedule", schedule=sched,
            test_err=round(out[sched][0], 2), std=round(out[sched][1], 2))
    # round-clock row: adaptive communication period (QSR) on the paper's
    # main-results lambda schedule — report comm volume next to error
    runs = [run_distributed(
        data, DPPFConfig(alpha=0.1, lam=0.5, tau=4,
                         lam_schedule="increasing", tau_schedule="qsr",
                         qsr_beta=QSR_BETA),
        M=M, steps=steps, seed=s) for s in SEEDS]
    errs = [r.test_err for r in runs]
    out["increasing+qsr"] = (float(np.mean(errs)), float(np.std(errs)))
    csv("ablate_schedule", schedule="increasing+qsr",
        test_err=round(out["increasing+qsr"][0], 2),
        std=round(out["increasing+qsr"][1], 2),
        comm_pct=round(float(np.mean([r.comm_pct for r in runs])), 1))
    best = min(out, key=lambda k: out[k][0])
    csv("ablate_schedule_summary", best=best)
    return out


if __name__ == "__main__":
    run()
