"""Paper §D.1 / Figure 7: is the dropped second term T2 (mean unit
direction) really negligible? We track ||T1||, ||T2||, ||T1+T2|| during DPPF
training and compare final errors of simplified vs exact updates."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import csv, default_data, run_distributed
from repro.configs import DPPFConfig
from repro.core import pullpush as pp


def run(steps=400, M=4):
    data = default_data()
    r_simple = run_distributed(
        data, DPPFConfig(alpha=0.1, lam=0.5, tau=4), M=M, steps=steps)
    r_exact = run_distributed(
        data, DPPFConfig(alpha=0.1, lam=0.5, tau=4, exact_second_term=True),
        M=M, steps=steps)
    # term norms at the final point
    stacked = jax.tree.map(lambda *ls: np.stack(ls), *r_simple.workers)
    stacked = jax.tree.map(jax.numpy.asarray, stacked)
    n1, n2, n12 = pp.push_terms_norms(stacked, lam_r=0.5 * M)
    csv("ablate_second_term",
        t1_norm=round(float(np.mean(np.asarray(n1))), 4),
        t2_norm=round(float(n2), 4),
        t1_plus_t2_norm=round(float(np.mean(np.asarray(n12))), 4),
        err_simplified=round(r_simple.test_err, 2),
        err_exact=round(r_exact.test_err, 2),
        t2_negligible=bool(float(n2) < 0.5 * float(np.mean(np.asarray(n1)))))


if __name__ == "__main__":
    run()
