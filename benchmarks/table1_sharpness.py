"""Paper Table 1 / §4.1: Kendall rank correlation between sharpness
measures and the generalization gap. Minima of varied quality are produced
by sweeping lr / weight decay / batch size / width (paper B.1), for both
single-worker and EASGD-distributed training; Inv. MV is computed from the
EASGD worker spread (it needs multiple workers — 'NA' for single, as in the
paper)."""
from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv, default_data, mlp_loss, run_distributed
from repro.configs import DPPFConfig
from repro.core import sharpness as sh
from repro.core.valley import mean_valley

GRID = {
    "lr": [0.02, 0.1],
    "wd": [0.0, 1e-3],
    "bs": [16, 128],
    "width": [32, 96],
}


def _full_batch(data, n=1024):
    return {"x": data["x_train"][:n], "y": data["y_train"][:n]}


def run(steps=300, M=4, kappa=2.0):
    data = default_data(noise=1.1)
    fb = _full_batch(data)
    loss_fn = lambda p, b: mlp_loss(p, b)[0]
    loss_on_train = lambda p: mlp_loss(p, fb)[0]

    for mode in ("single", "easgd"):
        gaps, measures = [], {k: [] for k in
                              ("eps_sharp", "fisher_rao", "lpf", "lam_max",
                               "trace", "frob", "inv_mv")}
        combos = list(itertools.product(*GRID.values()))
        for i, (lr, wd, bs, width) in enumerate(combos):
            if mode == "single":
                dcfg = DPPFConfig(consensus="ddp")
                r = run_distributed(data, dcfg, M=1, bs=bs, steps=steps,
                                    lr=lr, wd=wd, width=width, seed=i)
            else:
                dcfg = DPPFConfig(consensus="easgd", alpha=0.1, lam=0.0,
                                  push=False, tau=4)
                r = run_distributed(data, dcfg, M=M, bs=bs, steps=steps,
                                    lr=lr, wd=wd, width=width, seed=i)
            if r.train_err > 40.0:
                continue  # paper discards non-fit models
            gaps.append(r.gen_gap)
            p = r.params_avg
            key = jax.random.PRNGKey(i)
            measures["eps_sharp"].append(sh.eps_sharpness(loss_fn, p, fb))
            measures["fisher_rao"].append(sh.fisher_rao(loss_fn, p, fb))
            measures["lpf"].append(sh.lpf(loss_fn, p, fb, key, mcmc=10))
            hm = sh.hessian_measures(loss_fn, p, fb, key, lanczos_iters=10,
                                     hutchinson=4)
            measures["lam_max"].append(hm["lambda_max"])
            measures["trace"].append(hm["trace"])
            measures["frob"].append(hm["frob"])
            if mode == "easgd":
                mv = mean_valley(loss_on_train, r.workers, kappa=kappa,
                                 step=0.05, max_steps=120)
                measures["inv_mv"].append(mv["inv_mv"])

        for name, vals in measures.items():
            if not vals:
                csv("table1", mode=mode, measure=name, kendall="NA")
                continue
            tau = sh.kendall_tau(vals, gaps)
            csv("table1", mode=mode, measure=name, kendall=round(tau, 3),
                n=len(vals))


if __name__ == "__main__":
    run()
