"""Paper Figure 2 + 3 (§8.1): valley collapse without the push force, and
the pull/push tug-of-war. Weak pulls alone cannot keep workers apart; DPPF
stabilizes the consensus distance near lambda/alpha (Theorem 1)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv, default_data, run_distributed
from repro.configs import DPPFConfig


def run(steps=600, M=4):
    data = default_data()
    rows = {}
    for alpha in (0.0001, 0.005, 0.01, 0.05):
        r = run_distributed(
            data, DPPFConfig(consensus="simple_avg", alpha=alpha, lam=0.0,
                             push=False, tau=4),
            M=M, steps=steps, track_every=5)
        rows[f"pull_only(alpha={alpha})"] = r
    dppf = run_distributed(
        data, DPPFConfig(consensus="simple_avg", alpha=0.1, lam=0.5,
                         push=True, tau=4, lam_schedule="fixed"),
        M=M, steps=steps, track_every=5)
    rows["DPPF(a=0.1,l=0.5)"] = dppf

    for name, r in rows.items():
        h = r.history["consensus_dist"]
        early = float(np.mean(h[:3])) if h else 0.0
        csv("fig2", method=name, final_dist=round(r.consensus_dist, 4),
            early_dist=round(early, 4),
            collapsing=bool(r.consensus_dist < 0.5 * max(early, 1e-9)),
            test_err=round(r.test_err, 2))
    # tug-of-war phases (Fig 3): pull force alpha*dist vs push force lam
    h = dppf.history
    if h["step"]:
        mid = len(h["step"]) // 2
        csv("fig3", early_pull=round(h["pull"][0], 4),
            early_push=round(h["push"][0], 4),
            late_pull=round(h["pull"][-1], 4),
            late_push=round(h["push"][-1], 4),
            final_ratio_dist_over_lam_alpha=round(
                dppf.consensus_dist / (0.5 / 0.1), 3))
    return rows


if __name__ == "__main__":
    run()
